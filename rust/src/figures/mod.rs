//! Figure harness: regenerates every figure in the paper's evaluation
//! (body Figures 1–6, appendix Figures 7–16) as CSV series with the same
//! axes the paper plots. DESIGN.md §5 is the authoritative index.
//!
//! Datasets are the synthetic LEAF substitutes (DESIGN.md §3); the claim
//! being reproduced is the *shape* of each comparison (orderings,
//! crossovers, robustness), not absolute accuracies.
//!
//! Default scale is reduced so `quafl figures` completes on a laptop core
//! in minutes; `--paper-scale` restores the paper's n/s/rounds, and
//! `--smoke` clamps every arm to a seconds-scale run (the CI figure-smoke
//! job).
//!
//! ## §net — simulated-network arms (DESIGN.md §5 index)
//!
//! Two arms beyond the paper, enabled by the [`crate::net`] subsystem:
//!
//! - **`net_bw`** — bandwidth-skew sweep: QuAFL ± lattice quantization and
//!   uncompressed FedAvg under the `ideal` vs `mobile` profiles (Pareto
//!   uplink, skewed lognormal downlink). Under `ideal` the compressed and
//!   uncompressed QuAFL arms finish at the same simulated time; under
//!   `mobile` the uncompressed arms pay the full model's uplink every
//!   exchange and the sim-time ordering flips — the paper's communication-
//!   efficiency claim made visible on the time axis. Per-phase
//!   communication time is in each CSV (`comm_up_time`/`comm_down_time`).
//! - **`net_churn`** — availability sweep at the paper's large-fleet scale
//!   (n=300, s=30 with `--paper-scale`): always-on vs mild/heavy
//!   dropout-rejoin churn vs 50% duty-cycle windows. `short_rounds` in the
//!   summary counts rounds that ran under-strength.
//! - **`net_fleet`** — huge-fleet sweep beyond the paper's n=300 ceiling
//!   (n=10⁴, s=30 with `--paper-scale`): QuAFL vs FedBuff vs FedAvg under
//!   the `mobile` profile, feasible because the CoW fleet store
//!   ([`crate::fleet`]) keeps resident client-model memory O(touched·d).
//!   The summary's `peak_model_bytes` column quantifies it.
//! - **`select_churn`** — the four client-selection policies
//!   ([`crate::select`]: uniform, staleness-capped, fairness quota,
//!   loss-aware power-of-choice) for QuAFL and FedBuff at n=300/s=30
//!   (`--paper-scale`) on `mobile` under churn. The summary's
//!   `participation_gini`, `staleness_max`/`staleness_mean`, and
//!   `rejected` columns separate the policies.
//! - **`chaos`** — the failure-handling sweep ([`crate::fault`],
//!   docs/FAULTS.md): QuAFL under each seeded fault model in isolation
//!   (crash, drop, corrupt, straggle + deadline), then all three
//!   federated algorithms under the combined chaos profile with quorum
//!   aggregation. Also writes `BENCH_chaos.json` — recovery counters
//!   next to wall time, gated in CI against
//!   `bench/baselines/BENCH_chaos.json`.
//!
//! The same axes are scriptable as a grid via `quafl sweep`
//! (`--algorithms`, `--quantizers`, `--nets`, `--seeds` — see
//! [`run_sweep`]), with the network flags `--net`, `--net-up`,
//! `--net-down`, `--net-latency`, `--churn A/B`, `--duty P/F` accepted by
//! `run` and `sweep` alike.

use anyhow::{Context, Result};

use crate::config::{
    Algorithm, AveragingMode, ExperimentConfig, QuantizerKind,
};
use crate::coordinator;
use crate::data::{PartitionKind, SynthFamily};
use crate::fault::FaultConfig;
use crate::metrics::RunMetrics;
use crate::net::{AvailabilityKind, NetProfile, NetworkConfig};
use crate::select::SelectionKind;
use crate::util::csv::CsvWriter;

/// One experimental arm of a figure.
pub struct Arm {
    pub label: String,
    pub cfg: ExperimentConfig,
}

pub fn list() -> Vec<&'static str> {
    vec![
        "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
        "fig9", "fig10", "fig11", "fig13", "fig15", "fig16", "net_bw",
        "net_churn", "net_fleet", "select_churn", "chaos",
    ]
}

/// Clamp an arm to a seconds-scale run: same code paths, tiny horizon.
/// Used by `--smoke` (the CI figure-smoke job).
pub fn smoke_cfg(mut cfg: ExperimentConfig) -> ExperimentConfig {
    cfg.n = cfg.n.min(8);
    cfg.s = cfg.s.min(3).min(cfg.n);
    cfg.k = cfg.k.min(5);
    cfg.rounds = cfg.rounds.min(4);
    cfg.eval_every = cfg.eval_every.min(4);
    cfg.train_samples = cfg.train_samples.min(512);
    cfg.val_samples = cfg.val_samples.min(128);
    // A fleet-scale staleness cap can never bind inside a 4-round smoke;
    // clamp it so the bounded-staleness code paths actually run.
    if let SelectionKind::StalenessAware { cap } = &mut cfg.select {
        *cap = (*cap).min(2);
    }
    cfg
}

/// Headline columns shared by every summary CSV (figures and sweep);
/// [`summary_core_cells`] produces the matching row slice.
/// `peak_model_bytes` makes fleet-scale memory (the CoW store's
/// high-water mark, [`crate::fleet`]) visible in sweep output, not just
/// in benches; `participation_gini` and the staleness columns make the
/// selection policies ([`crate::select`]) comparable per row, and
/// `rejected` counts FedBuff arrivals the admission gate dropped.
const SUMMARY_CORE_HEADER: &[&str] = &[
    "final_acc", "final_val_loss", "sim_time", "total_bits", "comm_up_time",
    "comm_down_time", "short_rounds", "time_to_acc50", "peak_model_bytes",
    "participation_gini", "staleness_max", "staleness_mean", "rejected",
];

/// One formatted cell per [`SUMMARY_CORE_HEADER`] column.
fn summary_core_cells(m: &RunMetrics) -> Vec<String> {
    let last = m.points.last().copied();
    vec![
        format!("{:.4}", m.final_acc()),
        format!("{:.4}", m.final_loss()),
        format!("{:.1}", last.map(|p| p.sim_time).unwrap_or(0.0)),
        format!("{}", m.total_bits()),
        format!("{:.2}", last.map(|p| p.comm_up_time).unwrap_or(0.0)),
        format!("{:.2}", last.map(|p| p.comm_down_time).unwrap_or(0.0)),
        format!("{}", m.short_rounds),
        m.time_to_accuracy(0.5)
            .map(|t| format!("{t:.1}"))
            .unwrap_or_else(|| "never".into()),
        format!("{}", m.peak_model_bytes()),
        format!("{:.4}", m.participation_gini()),
        format!("{}", m.staleness_max()),
        format!("{:.2}", m.staleness_mean()),
        format!("{}", m.rejected_interactions),
    ]
}

/// Run a figure by id, writing one CSV per arm plus a summary row file.
/// `trace` threads the CLI's `--trace` path into every arm's config, so
/// one figure invocation accumulates a single JSONL trace across arms
/// (the sink appends).
pub fn run_figure(
    id: &str,
    out_dir: &str,
    paper_scale: bool,
    smoke: bool,
    trace: Option<&str>,
) -> Result<()> {
    let arms = arms_for(id, paper_scale)
        .with_context(|| format!("unknown figure {id:?} (known: {:?})", list()))?;
    std::fs::create_dir_all(out_dir)?;
    let mut header: Vec<&str> = vec!["arm"];
    header.extend_from_slice(SUMMARY_CORE_HEADER);
    header.extend_from_slice(&["final_train_loss", "p_zero_progress", "mean_h"]);
    let mut summary =
        CsvWriter::create(format!("{out_dir}/{id}_summary.csv"), &header)?;
    for arm in arms {
        let t0 = std::time::Instant::now();
        let mut cfg = if smoke { smoke_cfg(arm.cfg) } else { arm.cfg };
        if cfg.trace.is_none() {
            cfg.trace = trace.map(str::to_string);
        }
        let metrics = coordinator::run(&cfg)
            .with_context(|| format!("{id} arm {}", arm.label))?;
        let path = format!("{out_dir}/{id}_{}.csv", arm.label);
        metrics.write_csv(&path)?;
        let mut row = vec![arm.label.clone()];
        row.extend(summary_core_cells(&metrics));
        row.push(format!(
            "{:.4}",
            metrics.points.last().map(|p| p.train_loss).unwrap_or(f64::NAN)
        ));
        row.push(format!("{:.3}", metrics.zero_progress_fraction()));
        row.push(format!("{:.2}", metrics.mean_observed_steps()));
        summary.row_strs(&row)?;
        crate::log!(
            Info,
            "[figures] {id}/{}: acc={:.3} ({}s)",
            arm.label,
            metrics.final_acc(),
            t0.elapsed().as_secs()
        );
    }
    summary.flush()?;
    if id == "net_fleet" {
        write_fleet_bench(out_dir, smoke)?;
    }
    if id == "chaos" {
        write_chaos_bench(out_dir)?;
    }
    Ok(())
}

/// The configs behind `BENCH_fleet.json`: QuAFL rounds on the 16-dim
/// `tiny` family (442-param `mlp_tiny`, k=1, s=30) so the timing isolates
/// the round *engine* — availability, sampling, tracker — rather than SGD
/// math. Event-driven rows climb to n=10⁶ (the million-client smoke
/// round); legacy O(n) rows stop earlier and exist to show the scaling
/// gap. These run as-is in every mode, deliberately *not* smoke-clamped.
pub fn fleet_bench_configs(smoke: bool) -> Vec<(String, ExperimentConfig)> {
    const S: usize = 30;
    const ROUNDS: usize = 3;
    let event_ns: &[usize] = if smoke {
        &[10_000, 1_000_000]
    } else {
        &[10_000, 100_000, 1_000_000]
    };
    let legacy_ns: &[usize] = if smoke { &[10_000] } else { &[10_000, 100_000] };
    let bench_cfg = |n: usize, event_driven: bool| ExperimentConfig {
        algorithm: Algorithm::QuAFL,
        n,
        s: S,
        k: 1,
        rounds: ROUNDS,
        eval_every: ROUNDS,
        batch: 16,
        model: "mlp_tiny".into(),
        family: SynthFamily::Tiny,
        train_samples: n,
        val_samples: 64,
        quantizer: QuantizerKind::Lattice { bits: 10 },
        net: NetworkConfig {
            // Long up/down means keep churn-event traffic sparse, so the
            // measurement is queue/index cost, not transition volume.
            availability: AvailabilityKind::Churn {
                mean_up: 2000.0,
                mean_down: 500.0,
            },
            ..Default::default()
        },
        event_driven,
        ..ExperimentConfig::default()
    };
    let mut out = Vec::new();
    for &n in event_ns {
        out.push((format!("event_n{n}"), bench_cfg(n, true)));
    }
    for &n in legacy_ns {
        out.push((format!("legacy_n{n}"), bench_cfg(n, false)));
    }
    out
}

/// The first `BENCH_*.json` perf artifact: round wall-time vs fleet size
/// at fixed s, written alongside the `net_fleet` figure output. One row
/// per [`fleet_bench_configs`] entry, splitting one-time setup (dataset,
/// shards, clocks, availability index) from the per-round loop.
fn write_fleet_bench(out_dir: &str, smoke: bool) -> Result<()> {
    use crate::util::json::{self, Json};
    use std::collections::BTreeMap;

    let mut rows = Vec::new();
    for (label, cfg) in fleet_bench_configs(smoke) {
        let mode = if cfg.event_driven { "event" } else { "legacy" };
        let t0 = std::time::Instant::now();
        let mut ctx = coordinator::FlRun::new(&cfg)
            .with_context(|| format!("fleet bench {label}: setup"))?;
        let setup = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let metrics = crate::algorithms::quafl::run(&mut ctx)
            .with_context(|| format!("fleet bench {label}: run"))?;
        let run = t1.elapsed().as_secs_f64();
        crate::log!(
            Info,
            "[figures] net_fleet bench {label}: setup {setup:.2}s, {} rounds \
             in {run:.3}s (acc={:.3})",
            cfg.rounds,
            metrics.final_acc()
        );
        let mut row = BTreeMap::new();
        row.insert("n".into(), Json::Num(cfg.n as f64));
        row.insert("s".into(), Json::Num(cfg.s as f64));
        row.insert("mode".into(), Json::Str(mode.into()));
        row.insert("rounds".into(), Json::Num(cfg.rounds as f64));
        row.insert("setup_seconds".into(), Json::Num(setup));
        row.insert("run_seconds".into(), Json::Num(run));
        row.insert(
            "round_seconds".into(),
            Json::Num(run / cfg.rounds as f64),
        );
        rows.push(Json::Obj(row));
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("fleet_scaling".into()));
    doc.insert("figure".into(), Json::Str("net_fleet".into()));
    doc.insert("rows".into(), Json::Arr(rows));
    std::fs::write(
        format!("{out_dir}/BENCH_fleet.json"),
        json::to_string(&Json::Obj(doc)) + "\n",
    )?;
    Ok(())
}

/// The configs behind `BENCH_chaos.json`: one aggressive all-faults
/// chaos profile per algorithm plus a clean control, at a fixed
/// seconds-scale size. Deliberately identical in every mode (never
/// smoke-clamped), so the CI chaos row ids always match the committed
/// baseline ceilings in `bench/baselines/BENCH_chaos.json`.
pub fn chaos_bench_configs() -> Vec<(String, ExperimentConfig)> {
    let chaos = FaultConfig {
        crash: 0.1,
        drop: 0.2,
        corrupt: 0.1,
        straggle: 0.3,
        straggle_mult: 4.0,
        round_deadline: 60.0,
        quorum: 2,
        ..FaultConfig::default()
    };
    let mk = |algorithm: Algorithm,
              quantizer: QuantizerKind,
              fault: FaultConfig| ExperimentConfig {
        algorithm,
        quantizer,
        n: 24,
        s: 6,
        k: 5,
        rounds: 6,
        eval_every: 6,
        family: SynthFamily::Hard,
        train_samples: 2048,
        val_samples: 256,
        net: NetworkConfig {
            profile: NetProfile::preset("mobile").expect("preset"),
            ..Default::default()
        },
        fault,
        ..ExperimentConfig::default()
    };
    vec![
        (
            "quafl_clean".into(),
            mk(
                Algorithm::QuAFL,
                QuantizerKind::Lattice { bits: 10 },
                FaultConfig::default(),
            ),
        ),
        (
            "quafl_chaos".into(),
            mk(
                Algorithm::QuAFL,
                QuantizerKind::Lattice { bits: 10 },
                chaos.clone(),
            ),
        ),
        (
            "fedbuff_chaos".into(),
            mk(
                Algorithm::FedBuff,
                QuantizerKind::Qsgd { bits: 10 },
                chaos.clone(),
            ),
        ),
        (
            "fedavg_chaos".into(),
            mk(Algorithm::FedAvg, QuantizerKind::None, chaos),
        ),
    ]
}

/// The chaos-recovery perf/robustness artifact, written alongside the
/// `chaos` figure output: per [`chaos_bench_configs`] row, wall time
/// (the gated column — `wall_ns_total` rides the bench-compare gate,
/// [`crate::testing::compare::GATE_KEYS`]) plus the full
/// [`crate::fault::FaultCounters`] family so regressions in recovery
/// behaviour are visible in review, not just timing.
fn write_chaos_bench(out_dir: &str) -> Result<()> {
    use crate::util::json::{self, Json};
    use std::collections::BTreeMap;

    let mut rows = Vec::new();
    for (label, cfg) in chaos_bench_configs() {
        let t0 = std::time::Instant::now();
        let metrics = coordinator::run(&cfg)
            .with_context(|| format!("chaos bench {label}"))?;
        let wall_ns = t0.elapsed().as_nanos() as f64;
        let c = &metrics.fault;
        crate::log!(
            Info,
            "[figures] chaos bench {label}: acc={:.3} crashes={} retries={} \
             degraded={} ({:.3}s)",
            metrics.final_acc(),
            c.crashes,
            c.retries,
            c.degraded_rounds,
            wall_ns / 1e9
        );
        let mut row = BTreeMap::new();
        row.insert("arm".into(), Json::Str(label));
        row.insert("rounds".into(), Json::Num(cfg.rounds as f64));
        row.insert("wall_ns_total".into(), Json::Num(wall_ns));
        row.insert("final_acc".into(), Json::Num(metrics.final_acc()));
        row.insert(
            "sim_time".into(),
            Json::Num(
                metrics.points.last().map(|p| p.sim_time).unwrap_or(0.0),
            ),
        );
        row.insert("crashes".into(), Json::Num(c.crashes as f64));
        row.insert("evictions".into(), Json::Num(c.evictions as f64));
        row.insert("drops_up".into(), Json::Num(c.drops_up as f64));
        row.insert("drops_down".into(), Json::Num(c.drops_down as f64));
        row.insert("corruptions".into(), Json::Num(c.corruptions as f64));
        row.insert("retries".into(), Json::Num(c.retries as f64));
        row.insert("gave_up".into(), Json::Num(c.gave_up as f64));
        row.insert(
            "deadline_misses".into(),
            Json::Num(c.deadline_misses as f64),
        );
        row.insert("quorum_waits".into(), Json::Num(c.quorum_waits as f64));
        row.insert(
            "degraded_rounds".into(),
            Json::Num(c.degraded_rounds as f64),
        );
        row.insert("wasted_bits".into(), Json::Num(c.wasted_bits as f64));
        row.insert(
            "wasted_compute_s".into(),
            Json::Num(c.wasted_compute_time),
        );
        row.insert("backoff_s".into(), Json::Num(c.backoff_time));
        rows.push(Json::Obj(row));
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("chaos_recovery".into()));
    doc.insert("figure".into(), Json::Str("chaos".into()));
    doc.insert("rows".into(), Json::Arr(rows));
    std::fs::write(
        format!("{out_dir}/BENCH_chaos.json"),
        json::to_string(&Json::Obj(doc)) + "\n",
    )?;
    Ok(())
}

/// The axes of one `quafl sweep` grid: the cross product of algorithms ×
/// quantizers × network profiles × seeds, over a shared base config.
pub struct SweepSpec {
    pub algorithms: Vec<Algorithm>,
    pub quantizers: Vec<QuantizerKind>,
    /// (label, config) pairs — labels name the CSV files and summary rows
    pub nets: Vec<(String, NetworkConfig)>,
    pub seeds: Vec<u64>,
}

/// Short label for a quantizer choice in file names / summary rows.
pub fn quant_label(q: &QuantizerKind) -> String {
    match q {
        QuantizerKind::Lattice { bits } => format!("lattice{bits}"),
        QuantizerKind::Qsgd { bits } => format!("qsgd{bits}"),
        QuantizerKind::None => "fp32".into(),
    }
}

/// Grid runner behind `quafl sweep`: one run per cell, one CSV per cell
/// plus a `sweep_summary.csv` with the headline numbers (simulated time,
/// exact bits, per-phase communication time, under-strength rounds).
pub fn run_sweep(
    base: &ExperimentConfig,
    spec: &SweepSpec,
    out_dir: &str,
) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let mut header: Vec<&str> = vec!["algorithm", "quantizer", "net", "seed"];
    header.extend_from_slice(SUMMARY_CORE_HEADER);
    let mut summary =
        CsvWriter::create(format!("{out_dir}/sweep_summary.csv"), &header)?;
    let mut seen = std::collections::BTreeSet::new();
    for algo in &spec.algorithms {
        for quant in &spec.quantizers {
            // FedAvg and the baseline ignore the quantizer axis entirely
            // (full-precision models / no communication); collapse their
            // cells so the grid doesn't emit duplicate runs labeled as
            // distinct compressed arms.
            let quant = match algo {
                Algorithm::FedAvg | Algorithm::Baseline => QuantizerKind::None,
                _ => *quant,
            };
            for (net_label, net) in &spec.nets {
                for &seed in &spec.seeds {
                    let label = format!(
                        "{}_{}_{}_s{}",
                        algo.name(),
                        quant_label(&quant),
                        net_label,
                        seed
                    );
                    if !seen.insert(label.clone()) {
                        continue;
                    }
                    let cfg = ExperimentConfig {
                        algorithm: *algo,
                        quantizer: quant,
                        net: net.clone(),
                        seed,
                        ..base.clone()
                    };
                    let t0 = std::time::Instant::now();
                    let metrics = coordinator::run(&cfg)
                        .with_context(|| format!("sweep cell {label}"))?;
                    metrics.write_csv(&format!("{out_dir}/sweep_{label}.csv"))?;
                    let mut row = vec![
                        algo.name().to_string(),
                        quant_label(&quant),
                        net_label.clone(),
                        format!("{seed}"),
                    ];
                    row.extend(summary_core_cells(&metrics));
                    summary.row_strs(&row)?;
                    crate::log!(
                        Info,
                        "[sweep] {label}: acc={:.3} sim_time={:.1} ({}s)",
                        metrics.final_acc(),
                        metrics.points.last().map(|p| p.sim_time).unwrap_or(0.0),
                        t0.elapsed().as_secs()
                    );
                }
            }
        }
    }
    summary.flush()?;
    Ok(())
}

/// Convenience for tests and the summary table in EXPERIMENTS.md.
pub fn run_arms(arms: Vec<Arm>) -> Result<Vec<(String, RunMetrics)>> {
    arms.into_iter()
        .map(|a| coordinator::run(&a.cfg).map(|m| (a.label, m)))
        .collect()
}

fn scale(paper: bool, small: usize, full: usize) -> usize {
    if paper {
        full
    } else {
        small
    }
}

/// Base config shared by the figure experiments.
fn base(paper: bool) -> ExperimentConfig {
    ExperimentConfig {
        rounds: scale(paper, 60, 300),
        train_samples: scale(paper, 4000, 20_000),
        val_samples: 1024,
        eval_every: scale(paper, 10, 20),
        ..Default::default()
    }
}

pub fn arms_for(id: &str, paper: bool) -> Option<Vec<Arm>> {
    let b = base(paper);
    let arms = match id {
        // Fig 1: peers s ∈ {10,20,30,40}, n=100, 14-bit, non-iid, 30% slow.
        "fig1" => {
            let n = scale(paper, 40, 100);
            [1usize, 2, 3, 4]
                .iter()
                .map(|&m| {
                    let s = scale(paper, 4, 10) * m;
                    Arm {
                        label: format!("s{s}"),
                        cfg: ExperimentConfig {
                            algorithm: Algorithm::QuAFL,
                            n,
                            s,
                            family: SynthFamily::Celeb,
                            partition: PartitionKind::ByClass,
                            quantizer: QuantizerKind::Lattice { bits: 14 },
                            timing: crate::config::TimingConfig {
                                slow_fraction: 0.3,
                                ..Default::default()
                            },
                            // non-iid needs a longer horizon for the s
                            // ordering to separate from noise
                            rounds: b.rounds * 3,
                            eval_every: b.eval_every * 3,
                            ..b.clone()
                        },
                    }
                })
                .collect()
        }
        // Fig 2: bits b ∈ {8,10,12,32}, n=40, s=5, iid mnist.
        "fig2" => [8u8, 10, 12, 32]
            .iter()
            .map(|&bits| Arm {
                label: format!("b{bits}"),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::QuAFL,
                    n: scale(paper, 20, 40),
                    s: 5,
                    quantizer: if bits == 32 {
                        QuantizerKind::None
                    } else {
                        QuantizerKind::Lattice { bits }
                    },
                    ..b.clone()
                },
            })
            .collect(),
        // Fig 3: QuAFL (weighted + unweighted) vs FedAvg vs baseline, in
        // simulated time, hard family, 25% slow.
        "fig3" => {
            let mk = |label: &str, algo: Algorithm, weighted: bool| Arm {
                label: label.into(),
                cfg: ExperimentConfig {
                    algorithm: algo,
                    weighted,
                    family: SynthFamily::Hard,
                    n: 20,
                    s: 5,
                    quantizer: QuantizerKind::Lattice { bits: 12 },
                    ..b.clone()
                },
            };
            vec![
                mk("quafl_weighted", Algorithm::QuAFL, true),
                mk("quafl", Algorithm::QuAFL, false),
                Arm {
                    label: "fedavg".into(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::FedAvg,
                        family: SynthFamily::Hard,
                        n: 20,
                        s: 5,
                        quantizer: QuantizerKind::None,
                        ..b.clone()
                    },
                },
                Arm {
                    label: "baseline".into(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::Baseline,
                        family: SynthFamily::Hard,
                        n: 20,
                        s: 5,
                        rounds: b.rounds * 10,
                        eval_every: b.eval_every * 10,
                        ..b.clone()
                    },
                },
            ]
        }
        // Fig 4: averaging variants on non-iid celeb.
        "fig4" => [
            ("both", AveragingMode::Both),
            ("server_only", AveragingMode::ServerOnly),
            ("client_only", AveragingMode::ClientOnly),
        ]
        .iter()
        .map(|(label, mode)| Arm {
            label: label.to_string(),
            cfg: ExperimentConfig {
                algorithm: Algorithm::QuAFL,
                averaging: *mode,
                n: scale(paper, 40, 100),
                s: scale(paper, 8, 10),
                family: SynthFamily::Celeb,
                partition: PartitionKind::ByClass,
                quantizer: QuantizerKind::Lattice { bits: 14 },
                ..b.clone()
            },
        })
        .collect(),
        // Fig 5: lattice vs QSGD inside QuAFL, mnist.
        "fig5" => vec![
            Arm {
                label: "lattice".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::Lattice { bits: 10 },
                    ..b.clone()
                },
            },
            Arm {
                label: "qsgd".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::Qsgd { bits: 10 },
                    // QSGD on raw models needs a gentler lr to stay stable
                    // (the paper: "we had to perform careful tuning").
                    lr: 0.05,
                    ..b.clone()
                },
            },
        ],
        // Fig 6: QuAFL ± quantization vs FedBuff ± QSGD, sim time.
        "fig6" => vec![
            Arm {
                label: "quafl_lattice14".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::Lattice { bits: 14 },
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "quafl_fp32".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::None,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedbuff_fp32".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedBuff,
                    quantizer: QuantizerKind::None,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedbuff_qsgd14".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedBuff,
                    quantizer: QuantizerKind::Qsgd { bits: 14 },
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
        ],
        // Fig 7: K ∈ {5,10,20} (paper: FMNIST → hard family).
        "fig7" => [5usize, 10, 20]
            .iter()
            .map(|&k| Arm {
                label: format!("K{k}"),
                cfg: ExperimentConfig {
                    k,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            })
            .collect(),
        // Fig 8: s ∈ {4,8,16}.
        "fig8" => [4usize, 8, 16]
            .iter()
            .map(|&s| Arm {
                label: format!("s{s}"),
                cfg: ExperimentConfig {
                    s,
                    n: 20.max(s),
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            })
            .collect(),
        // Fig 9: server waiting time sweep.
        "fig9" => [2.0f64, 10.0, 30.0]
            .iter()
            .map(|&swt| Arm {
                label: format!("swt{}", swt as i64),
                cfg: ExperimentConfig {
                    timing: crate::config::TimingConfig {
                        swt,
                        ..Default::default()
                    },
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            })
            .collect(),
        // Fig 10: rounds-axis comparison baseline vs FedAvg vs QuAFL.
        "fig10" => vec![
            Arm {
                label: "baseline".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::Baseline,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedavg".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedAvg,
                    quantizer: QuantizerKind::None,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "quafl".into(),
                cfg: ExperimentConfig {
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
        ],
        // Fig 11/12: time vs acc & loss across algorithm variants (the CSV
        // carries both columns, so one run covers both panels).
        "fig11" | "fig12" => vec![
            Arm {
                label: "quafl_lattice".into(),
                cfg: ExperimentConfig {
                    family: SynthFamily::Hard,
                    quantizer: QuantizerKind::Lattice { bits: 10 },
                    ..b.clone()
                },
            },
            Arm {
                label: "quafl_fp32".into(),
                cfg: ExperimentConfig {
                    family: SynthFamily::Hard,
                    quantizer: QuantizerKind::None,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedavg".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedAvg,
                    family: SynthFamily::Hard,
                    quantizer: QuantizerKind::None,
                    ..b.clone()
                },
            },
            Arm {
                label: "baseline".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::Baseline,
                    family: SynthFamily::Hard,
                    rounds: b.rounds * 10,
                    eval_every: b.eval_every * 10,
                    ..b.clone()
                },
            },
        ],
        // Fig 13/14: large fleet (paper n=300, s=30).
        "fig13" | "fig14" => vec![Arm {
            label: "n300".into(),
            cfg: ExperimentConfig {
                n: scale(paper, 60, 300),
                s: scale(paper, 6, 30),
                family: SynthFamily::Hard,
                train_samples: scale(paper, 6000, 30_000),
                quantizer: QuantizerKind::Lattice { bits: 10 },
                ..b.clone()
            },
        }],
        // Fig 15: full convergence, n=20, s=5 — all methods to plateau.
        "fig15" => {
            let rounds = scale(paper, 150, 1000);
            vec![
                Arm {
                    label: "quafl".into(),
                    cfg: ExperimentConfig { rounds, ..b.clone() },
                },
                Arm {
                    label: "fedavg".into(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::FedAvg,
                        quantizer: QuantizerKind::None,
                        rounds,
                        ..b.clone()
                    },
                },
                Arm {
                    label: "baseline".into(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::Baseline,
                        rounds: rounds * 10,
                        eval_every: b.eval_every * 10,
                        ..b.clone()
                    },
                },
            ]
        }
        // §net net_bw: bandwidth-skew sweep — ideal vs mobile (Pareto
        // uplink) for QuAFL ± compression and uncompressed FedAvg. Under
        // ideal the compressed/uncompressed QuAFL arms tie on sim-time;
        // under mobile the uncompressed arms pay ~2.5x the uplink bits per
        // exchange (plus the straggler tail) and the ordering flips.
        "net_bw" => {
            let mobile = NetworkConfig {
                profile: NetProfile::preset("mobile").expect("preset"),
                ..Default::default()
            };
            let ideal = NetworkConfig::default();
            let mk = |label: &str,
                      algorithm: Algorithm,
                      quantizer: QuantizerKind,
                      net: &NetworkConfig| Arm {
                label: label.into(),
                cfg: ExperimentConfig {
                    algorithm,
                    quantizer,
                    net: net.clone(),
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            };
            let l10 = QuantizerKind::Lattice { bits: 10 };
            vec![
                mk("quafl_l10_ideal", Algorithm::QuAFL, l10, &ideal),
                mk("quafl_fp32_ideal", Algorithm::QuAFL, QuantizerKind::None, &ideal),
                mk("quafl_l10_mobile", Algorithm::QuAFL, l10, &mobile),
                mk("quafl_fp32_mobile", Algorithm::QuAFL, QuantizerKind::None, &mobile),
                mk("fedavg_fp32_mobile", Algorithm::FedAvg, QuantizerKind::None, &mobile),
            ]
        }
        // §net net_churn: availability sweep at the paper's large-fleet
        // scale (n=300/s=30 with --paper-scale). Transport stays ideal so
        // the churn effect is isolated; short_rounds lands in the summary.
        "net_churn" => {
            let n = scale(paper, 60, 300);
            let s = scale(paper, 6, 30);
            let avails: [(&str, AvailabilityKind); 4] = [
                ("always", AvailabilityKind::Always),
                (
                    "churn_mild",
                    AvailabilityKind::Churn { mean_up: 200.0, mean_down: 50.0 },
                ),
                (
                    "churn_heavy",
                    AvailabilityKind::Churn { mean_up: 60.0, mean_down: 60.0 },
                ),
                (
                    "duty50",
                    AvailabilityKind::DutyCycle { period: 120.0, on_fraction: 0.5 },
                ),
            ];
            avails
                .into_iter()
                .map(|(label, availability)| Arm {
                    label: label.to_string(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::QuAFL,
                        n,
                        s,
                        family: SynthFamily::Hard,
                        train_samples: scale(paper, 6000, 30_000),
                        quantizer: QuantizerKind::Lattice { bits: 10 },
                        net: NetworkConfig {
                            profile: NetProfile::Ideal,
                            availability,
                            ..Default::default()
                        },
                        ..b.clone()
                    },
                })
                .collect()
        }
        // §net net_fleet: huge-fleet sweep the CoW fleet store unlocks —
        // QuAFL vs FedBuff vs FedAvg at n=10⁴/s=30 (with --paper-scale;
        // n=2000/s=16 at default scale) under the `mobile` profile. Only
        // s clients are touched per round, so resident client-model
        // memory stays O(touched·d); the summary's peak_model_bytes
        // column shows it next to the dense layout's n·d·4.
        "net_fleet" => {
            let n = scale(paper, 2000, 10_000);
            let s = scale(paper, 16, 30);
            let mobile = NetworkConfig {
                profile: NetProfile::preset("mobile").expect("preset"),
                ..Default::default()
            };
            let mk = |label: &str,
                      algorithm: Algorithm,
                      quantizer: QuantizerKind| Arm {
                label: label.into(),
                cfg: ExperimentConfig {
                    algorithm,
                    quantizer,
                    n,
                    s,
                    family: SynthFamily::Hard,
                    train_samples: n.max(b.train_samples),
                    rounds: scale(paper, 20, 40),
                    eval_every: scale(paper, 10, 20),
                    net: mobile.clone(),
                    ..b.clone()
                },
            };
            vec![
                mk(
                    "quafl_lattice10",
                    Algorithm::QuAFL,
                    QuantizerKind::Lattice { bits: 10 },
                ),
                mk(
                    "fedbuff_qsgd10",
                    Algorithm::FedBuff,
                    QuantizerKind::Qsgd { bits: 10 },
                ),
                mk("fedavg_fp32", Algorithm::FedAvg, QuantizerKind::None),
            ]
        }
        // §select select_churn: the four selection policies
        // ([`crate::select`]) for QuAFL and FedBuff at the paper's
        // large-fleet scale (n=300/s=30 with --paper-scale) on the
        // `mobile` transport under churn — the regime where *which*
        // clients the server picks dominates. The summary's
        // participation_gini / staleness_max / staleness_mean / rejected
        // columns separate the policies; sim_time shows what each bias
        // costs or buys on the clock.
        "select_churn" => {
            let n = scale(paper, 60, 300);
            let s = scale(paper, 6, 30);
            let churn_net = NetworkConfig {
                profile: NetProfile::preset("mobile").expect("preset"),
                availability: AvailabilityKind::Churn {
                    mean_up: 120.0,
                    mean_down: 60.0,
                },
                ..Default::default()
            };
            // Cap = 2·(n/s): twice the expected uniform staleness, so it
            // binds on the churned tail without dominating selection.
            let policies: [(&str, SelectionKind); 4] = [
                ("uniform", SelectionKind::Uniform),
                (
                    "staleness",
                    SelectionKind::StalenessAware { cap: 2 * (n / s) as u64 },
                ),
                ("fairness", SelectionKind::Fairness),
                ("loss_poc", SelectionKind::LossPoc { candidates: None }),
            ];
            let mut arms = Vec::new();
            for (tag, algorithm, quantizer) in [
                ("quafl", Algorithm::QuAFL, QuantizerKind::Lattice { bits: 10 }),
                ("fedbuff", Algorithm::FedBuff, QuantizerKind::Qsgd { bits: 10 }),
            ] {
                for (plabel, select) in &policies {
                    arms.push(Arm {
                        label: format!("{tag}_{plabel}"),
                        cfg: ExperimentConfig {
                            algorithm,
                            quantizer,
                            n,
                            s,
                            family: SynthFamily::Hard,
                            train_samples: scale(paper, 6000, 30_000),
                            select: select.clone(),
                            net: churn_net.clone(),
                            ..b.clone()
                        },
                    });
                }
            }
            arms
        }
        // §fault chaos: the failure-handling sweep — QuAFL under each
        // fault model in isolation, then all three
        // federated algorithms under the combined chaos profile with a
        // round deadline and quorum aggregation ([`crate::fault`],
        // docs/FAULTS.md). Every arm runs the `mobile` transport so fault
        // pricing lands on a real clock; `quafl_clean` is the control
        // (same net, chaos disarmed). The summary's wasted columns and
        // `BENCH_chaos.json`'s recovery counters separate the arms.
        "chaos" => {
            let n = scale(paper, 24, 100);
            let s = scale(paper, 6, 10);
            let mobile = NetworkConfig {
                profile: NetProfile::preset("mobile").expect("preset"),
                ..Default::default()
            };
            let mk = |label: &str,
                      algorithm: Algorithm,
                      quantizer: QuantizerKind,
                      fault: FaultConfig| Arm {
                label: label.into(),
                cfg: ExperimentConfig {
                    algorithm,
                    quantizer,
                    n,
                    s,
                    family: SynthFamily::Hard,
                    net: mobile.clone(),
                    fault,
                    ..b.clone()
                },
            };
            // quorum=2 survives the smoke clamp (s is clamped to 3).
            let chaos = FaultConfig {
                crash: 0.05,
                drop: 0.1,
                corrupt: 0.05,
                straggle: 0.2,
                straggle_mult: 4.0,
                round_deadline: 60.0,
                quorum: 2,
                ..FaultConfig::default()
            };
            let l10 = QuantizerKind::Lattice { bits: 10 };
            vec![
                mk("quafl_clean", Algorithm::QuAFL, l10, FaultConfig::default()),
                mk(
                    "quafl_crash",
                    Algorithm::QuAFL,
                    l10,
                    FaultConfig { crash: 0.1, ..FaultConfig::default() },
                ),
                mk(
                    "quafl_drop",
                    Algorithm::QuAFL,
                    l10,
                    FaultConfig { drop: 0.2, ..FaultConfig::default() },
                ),
                mk(
                    "quafl_corrupt",
                    Algorithm::QuAFL,
                    l10,
                    FaultConfig { corrupt: 0.1, ..FaultConfig::default() },
                ),
                mk(
                    "quafl_straggle",
                    Algorithm::QuAFL,
                    l10,
                    FaultConfig {
                        straggle: 0.3,
                        straggle_mult: 4.0,
                        round_deadline: 60.0,
                        quorum: 2,
                        ..FaultConfig::default()
                    },
                ),
                mk("quafl_chaos", Algorithm::QuAFL, l10, chaos.clone()),
                mk(
                    "fedbuff_chaos",
                    Algorithm::FedBuff,
                    QuantizerKind::Qsgd { bits: 10 },
                    chaos.clone(),
                ),
                mk("fedavg_chaos", Algorithm::FedAvg, QuantizerKind::None, chaos),
            ]
        }
        // Fig 16: FedBuff+QSGD vs QuAFL+lattice at equal bit width.
        "fig16" => vec![
            Arm {
                label: "quafl_lattice10".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::Lattice { bits: 10 },
                    partition: PartitionKind::ByClass,
                    family: SynthFamily::Celeb,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedbuff_qsgd10".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedBuff,
                    quantizer: QuantizerKind::Qsgd { bits: 10 },
                    partition: PartitionKind::ByClass,
                    family: SynthFamily::Celeb,
                    ..b.clone()
                },
            },
        ],
        _ => return None,
    };
    Some(arms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_figure_has_arms_and_valid_configs() {
        for id in list() {
            for paper in [false, true] {
                let arms = arms_for(id, paper).unwrap_or_else(|| {
                    panic!("figure {id} has no arms");
                });
                assert!(!arms.is_empty());
                for arm in arms {
                    arm.cfg
                        .validate()
                        .unwrap_or_else(|e| panic!("{id}/{}: {e}", arm.label));
                }
            }
        }
    }

    #[test]
    fn unknown_figure_is_none() {
        assert!(arms_for("fig99", false).is_none());
    }

    #[test]
    fn smoke_clamp_keeps_every_figure_valid() {
        for id in list() {
            for arm in arms_for(id, true).unwrap() {
                let cfg = smoke_cfg(arm.cfg);
                cfg.validate()
                    .unwrap_or_else(|e| panic!("{id}/{}: {e}", arm.label));
                assert!(cfg.rounds <= 4);
                assert!(cfg.n <= 8);
            }
        }
    }

    #[test]
    fn net_bw_mixes_ideal_and_mobile() {
        let arms = arms_for("net_bw", false).unwrap();
        assert_eq!(arms.len(), 5);
        let ideal = arms.iter().filter(|a| a.cfg.net.profile.is_ideal()).count();
        assert_eq!(ideal, 2);
        assert!(arms.iter().any(|a| {
            a.cfg.algorithm == Algorithm::FedAvg && !a.cfg.net.profile.is_ideal()
        }));
    }

    #[test]
    fn net_churn_sweeps_availability_at_fleet_scale() {
        let arms = arms_for("net_churn", true).unwrap();
        assert_eq!(arms.len(), 4);
        assert!(arms.iter().all(|a| a.cfg.n == 300 && a.cfg.s == 30));
        assert!(arms
            .iter()
            .any(|a| matches!(a.cfg.net.availability, AvailabilityKind::Churn { .. })));
        assert!(arms.iter().any(|a| matches!(
            a.cfg.net.availability,
            AvailabilityKind::DutyCycle { .. }
        )));
    }

    #[test]
    fn net_fleet_reaches_ten_thousand_clients() {
        let arms = arms_for("net_fleet", true).unwrap();
        assert_eq!(arms.len(), 3);
        assert!(arms.iter().all(|a| a.cfg.n == 10_000 && a.cfg.s == 30));
        assert!(arms.iter().all(|a| !a.cfg.net.profile.is_ideal()));
        assert!(arms.iter().all(|a| a.cfg.train_samples >= a.cfg.n));
        let algos: Vec<Algorithm> =
            arms.iter().map(|a| a.cfg.algorithm).collect();
        assert!(algos.contains(&Algorithm::QuAFL));
        assert!(algos.contains(&Algorithm::FedBuff));
        assert!(algos.contains(&Algorithm::FedAvg));
        // Default scale stays a huge fleet, small enough for a laptop.
        let small = arms_for("net_fleet", false).unwrap();
        assert!(small.iter().all(|a| a.cfg.n == 2000));
    }

    #[test]
    fn fleet_bench_reaches_a_million_clients_and_validates() {
        for smoke in [false, true] {
            let cfgs = fleet_bench_configs(smoke);
            // Every bench config must be runnable as-is (never clamped).
            for (label, cfg) in &cfgs {
                cfg.validate().unwrap_or_else(|e| {
                    panic!("bench config {label} invalid: {e}")
                });
                assert_eq!(cfg.s, 30, "{label}");
                assert!(matches!(
                    cfg.net.availability,
                    AvailabilityKind::Churn { .. }
                ));
            }
            // The acceptance row: an event-driven n=10⁶ config in every
            // mode, including --smoke (the CI figure-smoke job).
            assert!(
                cfgs.iter().any(|(_, c)| c.n == 1_000_000 && c.event_driven),
                "smoke={smoke}: missing the million-client event row"
            );
            // Legacy rows exist for the scaling comparison but never at
            // the million-client scale (the O(n) walk is the point).
            assert!(cfgs.iter().any(|(_, c)| !c.event_driven));
            assert!(cfgs
                .iter()
                .all(|(_, c)| c.event_driven || c.n <= 100_000));
        }
    }

    #[test]
    fn select_churn_covers_both_algorithms_and_all_policies() {
        for paper in [false, true] {
            let arms = arms_for("select_churn", paper).unwrap();
            assert_eq!(arms.len(), 8);
            for algo in [Algorithm::QuAFL, Algorithm::FedBuff] {
                let of_algo: Vec<&Arm> =
                    arms.iter().filter(|a| a.cfg.algorithm == algo).collect();
                assert_eq!(of_algo.len(), 4, "{algo:?}");
                let names: std::collections::BTreeSet<&str> =
                    of_algo.iter().map(|a| a.cfg.select.name()).collect();
                assert_eq!(names.len(), 4, "{algo:?}: duplicate policies");
            }
            // Every arm runs under churn on a priced network, so the
            // policies have something to react to.
            assert!(arms.iter().all(|a| !a.cfg.net.profile.is_ideal()));
            assert!(arms.iter().all(|a| matches!(
                a.cfg.net.availability,
                AvailabilityKind::Churn { .. }
            )));
        }
        let paper_arms = arms_for("select_churn", true).unwrap();
        assert!(paper_arms.iter().all(|a| a.cfg.n == 300 && a.cfg.s == 30));
        // The smoke clamp keeps the staleness cap small enough to bind.
        for arm in arms_for("select_churn", true).unwrap() {
            let cfg = smoke_cfg(arm.cfg);
            if let SelectionKind::StalenessAware { cap } = cfg.select {
                assert!(cap <= 2, "smoke cap {cap} cannot bind in 4 rounds");
            }
        }
    }

    #[test]
    fn chaos_covers_every_fault_model_and_all_algorithms() {
        for paper in [false, true] {
            let arms = arms_for("chaos", paper).unwrap();
            assert_eq!(arms.len(), 8);
            let clean =
                arms.iter().find(|a| a.label == "quafl_clean").unwrap();
            assert!(!clean.cfg.fault.enabled(), "control must stay disarmed");
            assert_eq!(
                arms.iter().filter(|a| a.cfg.fault.enabled()).count(),
                7
            );
            // All three federated algorithms face the combined profile
            // (crash + drop + corrupt + straggle + deadline + quorum).
            for algo in
                [Algorithm::QuAFL, Algorithm::FedBuff, Algorithm::FedAvg]
            {
                assert!(
                    arms.iter().any(|a| a.cfg.algorithm == algo
                        && a.cfg.fault.crash > 0.0
                        && a.cfg.fault.drop > 0.0
                        && a.cfg.fault.round_deadline > 0.0
                        && a.cfg.fault.quorum > 1),
                    "{algo:?} missing a combined-chaos arm"
                );
            }
            // Fault time needs a priced clock to show up on.
            assert!(arms.iter().all(|a| !a.cfg.net.profile.is_ideal()));
            // The quorum must survive the smoke clamp (s drops to 3).
            for arm in arms {
                let label = arm.label;
                let cfg = smoke_cfg(arm.cfg);
                assert!(cfg.fault.quorum <= cfg.s, "{label}");
            }
        }
    }

    #[test]
    fn chaos_bench_configs_validate_and_arm_every_fault() {
        let cfgs = chaos_bench_configs();
        assert_eq!(cfgs.len(), 4);
        for (label, cfg) in &cfgs {
            cfg.validate()
                .unwrap_or_else(|e| panic!("chaos bench {label}: {e}"));
        }
        // Exactly one clean control; every armed row runs all four fault
        // models under a deadline + quorum (the acceptance scenario).
        assert_eq!(cfgs.iter().filter(|(_, c)| !c.fault.enabled()).count(), 1);
        for (label, cfg) in cfgs.iter().filter(|(_, c)| c.fault.enabled()) {
            let f = &cfg.fault;
            assert!(
                f.crash > 0.0
                    && f.drop > 0.0
                    && f.corrupt > 0.0
                    && f.straggle > 0.0,
                "{label}: all four fault models must be armed"
            );
            assert!(
                f.round_deadline > 0.0 && f.quorum == 2,
                "{label}: deadline + quorum must be armed"
            );
        }
    }

    #[test]
    fn quant_labels() {
        assert_eq!(quant_label(&QuantizerKind::Lattice { bits: 10 }), "lattice10");
        assert_eq!(quant_label(&QuantizerKind::Qsgd { bits: 8 }), "qsgd8");
        assert_eq!(quant_label(&QuantizerKind::None), "fp32");
    }

    #[test]
    fn fig1_sweeps_s_with_fixed_n() {
        let arms = arms_for("fig1", false).unwrap();
        let ss: Vec<usize> = arms.iter().map(|a| a.cfg.s).collect();
        assert_eq!(ss, vec![4, 8, 12, 16]);
        assert!(arms.iter().all(|a| a.cfg.partition == PartitionKind::ByClass));
    }

    #[test]
    fn fig2_includes_fp32_arm() {
        let arms = arms_for("fig2", false).unwrap();
        assert!(arms.iter().any(|a| a.cfg.quantizer == QuantizerKind::None));
    }

    #[test]
    fn fig16_same_bit_width_across_algorithms() {
        let arms = arms_for("fig16", false).unwrap();
        assert_eq!(arms[0].cfg.quantizer.bits(), arms[1].cfg.quantizer.bits());
        assert_eq!(arms[1].cfg.algorithm, Algorithm::FedBuff);
    }
}

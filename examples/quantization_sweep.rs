//! Quantization sweep (Figures 2, 5, 19): bits-per-coordinate vs final
//! accuracy and total bytes moved, for both quantizer families.
//!
//!     cargo run --release --example quantization_sweep
//!
//! Demonstrates the paper's two findings: (a) convergence saturates at
//! ~10 bits for the lattice scheme — >3x compression for free; (b) QSGD
//! needs careful tuning and converges worse at equal width because its
//! error scales with the *model norm*, not the model *distance*.

use quafl::config::{ExperimentConfig, QuantizerKind};
use quafl::coordinator;

fn main() -> anyhow::Result<()> {
    let base = ExperimentConfig {
        n: 20,
        s: 5,
        k: 10,
        rounds: 80,
        eval_every: 80,
        train_samples: 4000,
        val_samples: 512,
        ..Default::default()
    };

    println!(
        "{:<14} {:>5} {:>9} {:>9} {:>12} {:>8}",
        "quantizer", "bits", "acc", "loss", "MB_total", "ratio"
    );
    let mut fp32_mb = 0.0;
    for (label, quant, lr) in [
        ("fp32", QuantizerKind::None, 0.1),
        ("lattice", QuantizerKind::Lattice { bits: 6 }, 0.1),
        ("lattice", QuantizerKind::Lattice { bits: 8 }, 0.1),
        ("lattice", QuantizerKind::Lattice { bits: 10 }, 0.1),
        ("lattice", QuantizerKind::Lattice { bits: 12 }, 0.1),
        ("lattice", QuantizerKind::Lattice { bits: 14 }, 0.1),
        // QSGD transmits raw models; needs a gentler lr to stay stable
        // (the paper: "we had to perform careful tuning").
        ("qsgd", QuantizerKind::Qsgd { bits: 8 }, 0.05),
        ("qsgd", QuantizerKind::Qsgd { bits: 10 }, 0.05),
        ("qsgd", QuantizerKind::Qsgd { bits: 14 }, 0.05),
    ] {
        let cfg = ExperimentConfig { quantizer: quant, lr, ..base.clone() };
        let m = coordinator::run(&cfg).map_err(|e| anyhow::anyhow!("{e:#}"))?;
        let mb = m.total_bits() as f64 / 8e6;
        if quant == QuantizerKind::None {
            fp32_mb = mb;
        }
        println!(
            "{:<14} {:>5} {:>9.4} {:>9.4} {:>12.1} {:>8.2}",
            label,
            quant.bits(),
            m.final_acc(),
            m.final_loss(),
            mb,
            if mb > 0.0 { fp32_mb / mb } else { 0.0 },
        );
    }
    Ok(())
}

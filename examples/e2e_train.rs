//! End-to-end driver (DESIGN.md: the validation run recorded in
//! EXPERIMENTS.md §E2E): trains the federated MLP for a few hundred server
//! rounds **through the full three-layer stack** — Pallas kernels → JAX
//! fwd/bwd → AOT HLO text → Rust PJRT execution — under the QuAFL protocol
//! with lattice-quantized communication and heterogeneous client speeds,
//! and logs the loss curve to results/e2e_loss.csv.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!
//! Flags: --rounds N --n N --s N --model NAME --out PATH

use quafl::config::{ExperimentConfig, QuantizerKind, TimingConfig};
use quafl::coordinator;
use quafl::util::cli;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv);
    let rounds = args.get_usize("rounds", 300);
    let out = args.get_str("out", "results/e2e_loss.csv");

    let cfg = ExperimentConfig {
        use_xla: true, // the point of this example: artifacts on the hot path
        n: args.get_usize("n", 20),
        s: args.get_usize("s", 5),
        k: 10,
        rounds,
        eval_every: args.get_usize("eval-every", 10),
        model: args.get_str("model", "mlp"),
        quantizer: QuantizerKind::Lattice { bits: 10 },
        train_samples: 8000,
        val_samples: 1024,
        timing: TimingConfig { slow_fraction: 0.25, ..Default::default() },
        ..Default::default()
    };
    eprintln!(
        "e2e: QuAFL over PJRT artifacts — model={} d={} n={} s={} rounds={}",
        cfg.model,
        quafl::model::ModelSpec::by_name(&cfg.model).unwrap().num_params(),
        cfg.n,
        cfg.s,
        cfg.rounds
    );

    let t0 = std::time::Instant::now();
    let metrics = coordinator::run(&cfg).map_err(|e| anyhow::anyhow!("{e:#}"))?;
    let wall = t0.elapsed().as_secs_f64();

    for p in &metrics.points {
        println!(
            "round={:<5} sim_time={:<9.1} steps={:<7} train_loss={:.4} val_loss={:.4} val_acc={:.4}",
            p.round, p.sim_time, p.total_client_steps, p.train_loss, p.val_loss, p.val_acc
        );
    }
    metrics.write_csv(&out)?;
    println!(
        "\n[e2e] wall={:.1}s ({:.1} rounds/s) | final acc={:.4} | bits={:.1}MB | P[H=0]={:.3} | wrote {out}",
        wall,
        cfg.rounds as f64 / wall,
        metrics.final_acc(),
        metrics.total_bits() as f64 / 8e6,
        metrics.zero_progress_fraction(),
    );
    anyhow::ensure!(
        metrics.final_loss() < metrics.points[0].val_loss * 0.5,
        "loss did not decrease enough — e2e validation failed"
    );
    println!("[e2e] OK: loss curve validates the full stack");
    Ok(())
}

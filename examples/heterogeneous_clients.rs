//! Heterogeneity study (the workloads motivating the paper's intro):
//! non-i.i.d. data × heterogeneous client speeds.
//!
//!     cargo run --release --example heterogeneous_clients
//!
//! Runs QuAFL across the heterogeneity grid — {iid, dirichlet(0.3),
//! by-class} × {0%, 30%, 60% slow clients} — and reports final accuracy,
//! the measured P[H_i = 0] (the paper reports 27% for slow clients in the
//! Figure 1 setup), and the weighted-variant improvement.

use quafl::config::{ExperimentConfig, TimingConfig};
use quafl::coordinator;
use quafl::data::{PartitionKind, SynthFamily};

fn main() -> anyhow::Result<()> {
    let base = ExperimentConfig {
        n: 30,
        s: 8,
        k: 10,
        rounds: 80,
        eval_every: 80,
        family: SynthFamily::Celeb,
        train_samples: 3000,
        val_samples: 512,
        ..Default::default()
    };

    println!(
        "{:<16} {:>10} {:>9} {:>9} {:>9} {:>8}",
        "partition", "slow_frac", "acc", "acc_wtd", "P[H=0]", "meanH"
    );
    for (pname, part) in [
        ("iid", PartitionKind::Iid),
        ("dirichlet(0.3)", PartitionKind::Dirichlet(0.3)),
        ("by-class", PartitionKind::ByClass),
    ] {
        for slow in [0.0, 0.3, 0.6] {
            let cfg = ExperimentConfig {
                partition: part,
                timing: TimingConfig { slow_fraction: slow, ..Default::default() },
                ..base.clone()
            };
            let unweighted =
                coordinator::run(&cfg).map_err(|e| anyhow::anyhow!("{e:#}"))?;
            let weighted = coordinator::run(&ExperimentConfig {
                weighted: true,
                ..cfg
            })
            .map_err(|e| anyhow::anyhow!("{e:#}"))?;
            println!(
                "{:<16} {:>10.1} {:>9.4} {:>9.4} {:>9.3} {:>8.2}",
                pname,
                slow,
                unweighted.final_acc(),
                weighted.final_acc(),
                unweighted.zero_progress_fraction(),
                unweighted.mean_observed_steps(),
            );
        }
    }
    println!(
        "\nReading: accuracy decreases with heterogeneity on both axes; \
         QuAFL stays convergent even with 60% slow clients and fully \
         class-disjoint shards, and speed-weighting (η_i = H_min/H_i) helps \
         most when speeds are heterogeneous."
    );
    Ok(())
}

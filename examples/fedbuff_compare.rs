//! QuAFL vs FedBuff (Figures 6/16): the asynchronous-FL comparison.
//!
//!     cargo run --release --example fedbuff_compare
//!
//! Prints time-aligned accuracy trajectories for the four arms (each ±
//! quantization at the same bit width) plus compute/communication budgets,
//! so the trade-off the paper discusses is visible: FedBuff burns every
//! client's compute continuously, QuAFL samples s clients per round and
//! still folds in partial progress from slow ones; quantization costs
//! QuAFL (position-aware lattice) less than FedBuff (norm-scaled QSGD).

use quafl::config::{Algorithm, ExperimentConfig, QuantizerKind, TimingConfig};
use quafl::coordinator;
use quafl::data::{PartitionKind, SynthFamily};
use quafl::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let base = ExperimentConfig {
        n: 20,
        s: 5,
        k: 10,
        rounds: 80,
        eval_every: 8,
        family: SynthFamily::Hard,
        partition: PartitionKind::ByClass,
        train_samples: 3000,
        val_samples: 512,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..Default::default()
    };
    let arms: Vec<(&str, ExperimentConfig)> = vec![
        (
            "quafl+lattice10",
            ExperimentConfig {
                quantizer: QuantizerKind::Lattice { bits: 10 },
                ..base.clone()
            },
        ),
        ("quafl fp32", ExperimentConfig { quantizer: QuantizerKind::None, ..base.clone() }),
        (
            "fedbuff+qsgd10",
            ExperimentConfig {
                algorithm: Algorithm::FedBuff,
                quantizer: QuantizerKind::Qsgd { bits: 10 },
                ..base.clone()
            },
        ),
        (
            "fedbuff fp32",
            ExperimentConfig {
                algorithm: Algorithm::FedBuff,
                quantizer: QuantizerKind::None,
                ..base.clone()
            },
        ),
    ];

    let mut results: Vec<(&str, RunMetrics)> = Vec::new();
    for (label, cfg) in arms {
        let m = coordinator::run(&cfg).map_err(|e| anyhow::anyhow!("{e:#}"))?;
        results.push((label, m));
    }

    println!(
        "{:<16} {:>9} {:>9} {:>12} {:>12} {:>10}",
        "arm", "acc", "loss", "client_steps", "MB_moved", "sim_time"
    );
    for (label, m) in &results {
        let last = m.points.last().unwrap();
        println!(
            "{:<16} {:>9.4} {:>9.4} {:>12} {:>12.1} {:>10.1}",
            label,
            m.final_acc(),
            m.final_loss(),
            last.total_client_steps,
            m.total_bits() as f64 / 8e6,
            last.sim_time,
        );
    }

    // Quantization cost per algorithm family (the Figure 16 takeaway).
    let acc = |l: &str| {
        results.iter().find(|(x, _)| *x == l).unwrap().1.final_acc()
    };
    println!(
        "\nquantization cost: quafl {:+.4} | fedbuff {:+.4}",
        acc("quafl fp32") - acc("quafl+lattice10"),
        acc("fedbuff fp32") - acc("fedbuff+qsgd10"),
    );
    Ok(())
}

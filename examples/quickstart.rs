//! Quickstart: the smallest complete QuAFL run.
//!
//!     cargo run --release --example quickstart
//!
//! Trains the paper's MNIST-style MLP federated across 20 clients with
//! 10-bit lattice-quantized communication on the native engine, and prints
//! the convergence table. See `e2e_train` for the full XLA-artifact path.

use quafl::config::ExperimentConfig;
use quafl::coordinator;

fn main() -> anyhow::Result<()> {
    // Everything has a sensible default; this is the whole API surface a
    // downstream user needs for a first run.
    let cfg = ExperimentConfig {
        n: 20,                    // clients
        s: 5,                     // sampled per round
        k: 10,                    // max local steps between interactions
        rounds: 100,              // server rounds
        eval_every: 10,
        ..Default::default()      // mlp, synthetic MNIST, lattice:10, iid
    };

    println!("QuAFL quickstart: n={} s={} K={} quant={:?}", cfg.n, cfg.s, cfg.k, cfg.quantizer);
    let metrics = coordinator::run(&cfg).map_err(|e| anyhow::anyhow!("{e:#}"))?;

    println!("{:>6} {:>10} {:>10} {:>9} {:>9}", "round", "sim_time", "steps", "val_loss", "val_acc");
    for p in &metrics.points {
        println!(
            "{:>6} {:>10.1} {:>10} {:>9.4} {:>9.4}",
            p.round, p.sim_time, p.total_client_steps, p.val_loss, p.val_acc
        );
    }
    println!(
        "\nfinal accuracy {:.1}% | total communication {:.1} MB (vs {:.1} MB uncompressed)",
        metrics.final_acc() * 100.0,
        metrics.total_bits() as f64 / 8e6,
        metrics.total_bits() as f64 / 8e6 * 32.0 / 10.0,
    );
    Ok(())
}

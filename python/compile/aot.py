"""AOT compile path: lower the L2 model functions to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser on the Rust side reassigns ids and round-trips cleanly.

Emits, per model in ``model.MODELS``:

    artifacts/<name>_train_step.hlo.txt   params..., x(B,din), y(B,C), lr -> (params'..., loss)
    artifacts/<name>_eval.hlo.txt         params..., x(Be,din), y(Be,C)  -> (loss_sum, correct)

plus ``artifacts/meta.json`` describing shapes/arg order for the Rust
runtime, and (with --report) a §Perf structural report for the kernels.

Python runs ONCE (`make artifacts`); it is never on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import dense as dense_k

TRAIN_BATCH = 32
EVAL_BATCH = 256


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def specs_for(sizes, batch):
    f32 = jnp.float32
    params = [
        jax.ShapeDtypeStruct(shape, f32)
        for shape, _ in model.param_shapes(sizes)
    ]
    x = jax.ShapeDtypeStruct((batch, sizes[0]), f32)
    y = jax.ShapeDtypeStruct((batch, sizes[-1]), f32)
    return params, x, y


def lower_train_step(sizes, batch):
    params, x, y = specs_for(sizes, batch)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    def fn(*args):
        n = len(params)
        p, xx, yy, llr = list(args[:n]), args[n], args[n + 1], args[n + 2]
        return model.train_step(p, xx, yy, llr)

    return jax.jit(fn).lower(*params, x, y, lr)


def lower_train_k_steps(sizes, batch, k):
    params, x, y = specs_for(sizes, batch)
    f32 = jnp.float32
    xs = jax.ShapeDtypeStruct((k, batch, sizes[0]), f32)
    ys = jax.ShapeDtypeStruct((k, batch, sizes[-1]), f32)
    lr = jax.ShapeDtypeStruct((), f32)
    h = jax.ShapeDtypeStruct((), jnp.int32)
    _ = (x, y)

    def fn(*args):
        n = len(params)
        p = list(args[:n])
        return model.train_k_steps(p, args[n], args[n + 1], args[n + 2],
                                   args[n + 3])

    return jax.jit(fn).lower(*params, xs, ys, lr, h)


def lower_eval(sizes, batch):
    params, x, y = specs_for(sizes, batch)

    def fn(*args):
        n = len(params)
        p, xx, yy = list(args[:n]), args[n], args[n + 1]
        return model.eval_step(p, xx, yy)

    return jax.jit(fn).lower(*params, x, y)


def perf_report(sizes, batch):
    """Structural §Perf estimates for every matmul in fwd+bwd (DESIGN §7)."""
    rep = {}
    for i in range(len(sizes) - 1):
        m, k, n = batch, sizes[i], sizes[i + 1]
        rep[f"fwd_layer{i}"] = dense_k.vmem_report(m, k, n)
        rep[f"bwd_gx_layer{i}"] = dense_k.vmem_report(m, n, k)
        rep[f"bwd_gw_layer{i}"] = dense_k.vmem_report(k, m, n)
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(model.MODELS))
    ap.add_argument("--train-batch", type=int, default=TRAIN_BATCH)
    ap.add_argument("--eval-batch", type=int, default=EVAL_BATCH)
    ap.add_argument("--k-max", type=int, default=10,
                    help="max local steps K baked into the fused "
                         "train_k_steps artifact (§Perf L2)")
    ap.add_argument("--report", action="store_true",
                    help="also emit perf_report.json (§Perf structural stats)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    meta = {"train_batch": args.train_batch, "eval_batch": args.eval_batch,
            "models": {}}
    reports = {}
    for name in args.models.split(","):
        sizes = model.MODELS[name]
        tl = lower_train_step(sizes, args.train_batch)
        text = to_hlo_text(tl)
        tp = os.path.join(args.out_dir, f"{name}_train_step.hlo.txt")
        with open(tp, "w") as f:
            f.write(text)
        el = lower_eval(sizes, args.eval_batch)
        etext = to_hlo_text(el)
        ep = os.path.join(args.out_dir, f"{name}_eval.hlo.txt")
        with open(ep, "w") as f:
            f.write(etext)
        kl = lower_train_k_steps(sizes, args.train_batch, args.k_max)
        ktext = to_hlo_text(kl)
        kp = os.path.join(args.out_dir, f"{name}_train_k{args.k_max}.hlo.txt")
        with open(kp, "w") as f:
            f.write(ktext)
        meta["models"][name] = {
            "sizes": sizes,
            "num_params": model.num_params(sizes),
            "param_shapes": [
                {"name": n_, "shape": list(s)} for s, n_ in
                model.param_shapes(sizes)
            ],
            "train_step": os.path.basename(tp),
            "eval": os.path.basename(ep),
            "train_k": os.path.basename(kp),
            "k_max": args.k_max,
        }
        reports[name] = perf_report(sizes, args.train_batch)
        print(f"[aot] {name}: train_step={len(text)}B eval={len(etext)}B "
              f"d={model.num_params(sizes)}")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    if args.report:
        with open(os.path.join(args.out_dir, "perf_report.json"), "w") as f:
            json.dump(reports, f, indent=2)
    print(f"[aot] wrote artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()

"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact (up to float error)
counterpart here; pytest + hypothesis assert allclose between the two over
randomized shapes and inputs. The references are also used by tests to check
the hand-written custom_vjp backward passes in ``model.py`` against
``jax.grad`` of the reference composition.
"""

import jax.numpy as jnp


def dense_ref(x, w, b):
    """y = x @ w + b, float32 accumulation. x: (M, K), w: (K, N), b: (N,)."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b


def matmul_ref(x, w):
    """y = x @ w, float32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def relu_ref(x):
    return jnp.maximum(x, 0.0)


def softmax_xent_ref(logits, y_onehot):
    """Row-wise softmax cross-entropy.

    Returns (loss_per_row, probs) — probs are kept for the backward pass:
    d loss / d logits = (probs - y_onehot) / batch (for mean reduction).
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / s
    logp = logits - m - jnp.log(s)
    loss = -jnp.sum(y_onehot * logp, axis=-1)
    return loss, probs


def mlp_forward_ref(params, x):
    """Reference MLP forward: dense -> relu -> ... -> dense (logits).

    ``params`` is a flat list [w0, b0, w1, b1, ...].
    """
    h = x
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = dense_ref(h, w, b)
        if i < n_layers - 1:
            h = relu_ref(h)
    return h


def mlp_loss_ref(params, x, y_onehot):
    """Mean softmax cross-entropy of the reference MLP."""
    logits = mlp_forward_ref(params, x)
    loss, _ = softmax_xent_ref(logits, y_onehot)
    return jnp.mean(loss)

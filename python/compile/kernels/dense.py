"""L1 Pallas kernel: tiled dense layer (x @ w + b) with VMEM-sized blocks.

TPU-oriented design (see DESIGN.md §Hardware-Adaptation):

- The grid is (M/bm, N/bn, K/bk); each program instance owns a (bm, bn)
  output tile held in the output block across the K axis ("revisiting"
  schedule: the K grid dimension is innermost, so the same output block is
  live in VMEM while partial products accumulate into it).
- Block shapes are chosen so the per-step working set
  ``bm*bk + bk*bn + bm*bn`` floats stays within a VMEM budget (default
  2 MiB), and the inner ``jnp.dot`` maps onto MXU-shaped (multiple-of-8 x
  multiple-of-128) tiles where the true dims allow it.
- Inputs whose dims do not divide the block shape are zero-padded by the
  wrapper; zero columns/rows contribute nothing to the matmul and the
  result is sliced back.

On this image Pallas runs ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); correctness is asserted against ``ref.dense_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget (floats) for one grid step's working set. 2 MiB / 4 bytes.
_VMEM_BUDGET_F32 = 2 * 1024 * 1024 // 4


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_blocks(m: int, k: int, n: int):
    """Choose (bm, bk, bn) for a (m,k) @ (k,n) matmul.

    Heuristic: favour MXU-friendly tiles (sublane multiple of 8, lane
    multiple of 128) capped at the actual dims, shrinking bk until the
    working set fits the VMEM budget. Small output dims (n < 96, the MLP
    heads here) use 8-aligned lanes instead of padding to 128 — a 12.8x
    compute saving for the 10-class head in interpret mode; a real TPU
    pads lanes in-register at no FLOP cost, so this does not change the
    §Perf VMEM story (measured in EXPERIMENTS.md §Perf L2).
    """
    bm = min(_round_up(m, 8), 128)
    if n >= 96:
        bn = min(_round_up(n, 128), 256)
    else:
        bn = _round_up(n, 8)
    # Prefer a single K block when it fits (no K padding, no revisits).
    bk = min(_round_up(k, 8), 1024)
    while bm * bk + bk * bn + bm * bn > _VMEM_BUDGET_F32 and bk > 128:
        bk //= 2
        bk = _round_up(bk, 8)
    return bm, bk, bn


def vmem_report(m: int, k: int, n: int) -> dict:
    """Analytic VMEM-footprint / MXU-utilization estimate for DESIGN §Perf.

    interpret=True gives no hardware timings, so we report the structural
    quantities that determine TPU efficiency: per-step VMEM bytes, the
    fraction of MXU-aligned tile area that is real data (utilization), and
    HBM traffic per output element.
    """
    bm, bk, bn = pick_blocks(m, k, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    vmem_bytes = 4 * (bm * bk + bk * bn + bm * bn)
    mxu_util = (m * k * n) / (mp * kp * np_)
    # Each x block is read N/bn times, each w block M/bm times.
    hbm_reads = mp * kp * (np_ // bn) + kp * np_ * (mp // bm)
    return {
        "blocks": (bm, bk, bn),
        "padded": (mp, kp, np_),
        "vmem_bytes": vmem_bytes,
        "mxu_utilization": mxu_util,
        "hbm_read_floats": hbm_reads,
    }


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    """One (bm, bn) output tile; K axis (program_id 2) accumulates."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b_ref[...], o_ref.shape)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad2(a, m0, m1):
    p0, p1 = m0 - a.shape[0], m1 - a.shape[1]
    if p0 == 0 and p1 == 0:
        return a
    return jnp.pad(a, ((0, p0), (0, p1)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense(x, w, b, interpret=True):
    """Pallas tiled ``x @ w + b``. x: (M, K) f32, w: (K, N) f32, b: (N,) f32."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bk, bn = pick_blocks(m, k, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = _pad2(x, mp, kp)
    wp = _pad2(w, kp, np_)
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _dense_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(x, w, interpret=True):
    """Pallas tiled ``x @ w`` (no bias) — used by the hand-written backward."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bk, bn = pick_blocks(m, k, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = _pad2(x, mp, kp)
    wp = _pad2(w, kp, np_)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]

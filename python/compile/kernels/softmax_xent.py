"""L1 Pallas kernel: fused row-wise softmax cross-entropy.

Fuses max / exp / sum / log and the one-hot reduction into a single pass
over a (bm, C) row block, so the logits make one HBM->VMEM trip instead of
three (softmax, log, reduce). Emits both the per-row loss and the softmax
probabilities; the latter are the residual for the hand-written backward
pass in model.py (d loss / d logits = probs - y_onehot, scaled).

The class dimension C is small for every model in this repo (10 classes),
so one block spans all of C; the grid tiles only rows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _softmax_xent_kernel(logits_ref, y_ref, loss_ref, probs_ref):
    z = logits_ref[...]
    y = y_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    probs_ref[...] = e / s
    logp = z - m - jnp.log(s)
    loss_ref[...] = -jnp.sum(y * logp, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def softmax_xent(logits, y_onehot, interpret=True):
    """Returns (loss_per_row (M,), probs (M, C)).

    Rows are zero-padded to the block size; a padded row has all-zero
    one-hot so its loss contribution is log(C_padded-sum...) times 0 = 0
    only for the y*logp term — we therefore slice the outputs back to M
    and padded rows never leak into results.
    """
    m, c = logits.shape
    assert y_onehot.shape == (m, c)
    bm = min(_round_up(m, 8), 128)
    mp = _round_up(m, bm)
    if mp != m:
        logits = jnp.pad(logits, ((0, mp - m), (0, 0)))
        y_onehot = jnp.pad(y_onehot, ((0, mp - m), (0, 0)))
    grid = (mp // bm,)
    loss, probs = pl.pallas_call(
        _softmax_xent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp, c), jnp.float32),
        ],
        interpret=interpret,
    )(logits, y_onehot)
    return loss[:m], probs[:m]

"""L2: the client model — MLP forward/backward + SGD step, built on the
L1 Pallas kernels.

Structure mirrors the paper's MNIST setup (a (784, 32, 10) MLP trained with
SGD on softmax cross-entropy, Appendix A.3); wider/deeper variants scale the
parameter dimension d, which is what the quantizer and the protocol see.

The backward pass is hand-written (custom_vjp) in terms of the same Pallas
matmul kernel, so the *entire* fwd+bwd+update lowers into one HLO module
with the kernels inlined — Python never runs at training time; the Rust
coordinator executes the AOT artifact per local SGD step.

Functions here treat parameters as a flat list [w0, b0, w1, b1, ...]
matching ``ModelSpec`` on the Rust side (see rust/src/model/).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import dense as dense_k
from .kernels import softmax_xent as sx_k

# Model zoo: name -> layer sizes. Must match rust/src/model/mod.rs.
MODELS = {
    "mlp": [784, 32, 10],
    "mlp_wide": [784, 256, 10],
    "mlp_deep": [784, 256, 128, 10],
    "mlp_tiny": [16, 16, 10],
}


# --------------------------------------------------------------------------
# Differentiable primitives over the Pallas kernels.
# --------------------------------------------------------------------------

@jax.custom_vjp
def dense(x, w, b):
    return dense_k.dense(x, w, b)


def _dense_fwd(x, w, b):
    return dense_k.dense(x, w, b), (x, w)


def _dense_bwd(res, gy):
    x, w = res
    # gx = gy @ w^T ; gw = x^T @ gy ; gb = sum(gy). All matmuls are the
    # Pallas kernel; transposes happen at the HLO level outside the kernel.
    gx = dense_k.matmul(gy, w.T)
    gw = dense_k.matmul(x.T, gy)
    gb = jnp.sum(gy, axis=0)
    return gx, gw, gb


dense.defvjp(_dense_fwd, _dense_bwd)


@jax.custom_vjp
def mean_softmax_xent(logits, y_onehot):
    loss, _ = sx_k.softmax_xent(logits, y_onehot)
    return jnp.mean(loss)


def _msx_fwd(logits, y_onehot):
    loss, probs = sx_k.softmax_xent(logits, y_onehot)
    return jnp.mean(loss), (probs, y_onehot)


def _msx_bwd(res, g):
    probs, y_onehot = res
    m = probs.shape[0]
    glogits = (probs - y_onehot) * (g / m)
    return glogits, None


mean_softmax_xent.defvjp(_msx_fwd, _msx_bwd)


# --------------------------------------------------------------------------
# Model functions.
# --------------------------------------------------------------------------

def forward(params, x):
    """Logits of the MLP. params = [w0, b0, w1, b1, ...]."""
    h = x
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = dense(h, w, b)
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def loss_fn(params, x, y_onehot):
    return mean_softmax_xent(forward(params, x), y_onehot)


def train_step(params, x, y_onehot, lr):
    """One SGD step. Returns (new_params..., loss). This is the function
    the Rust coordinator executes once per simulated client-local step."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y_onehot)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new_params) + (loss,)


def grad_step(params, x, y_onehot, lr):
    """Scaled gradient (lr * g) without applying it — lets the coordinator
    accumulate h-tilde exactly as Algorithm 1 writes it. Returns
    (lr*g_0, ..., loss)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y_onehot)
    return tuple(lr * g for g in grads) + (loss,)


def train_k_steps(params, xs, ys, lr, h):
    """Up to K SGD steps in ONE lowered module (K = xs.shape[0]).

    §Perf L2 optimization: a single PJRT dispatch costs ~1.5 ms of fixed
    overhead on this image; QuAFL clients take h ≤ K steps per
    interaction, so fusing the burst into one fori_loop amortizes the
    dispatch K-fold. Steps with index ≥ h are masked (lr and loss zeroed),
    so the artifact is shape-specialized to K but *value*-parameterized by
    the realized h.

    xs: (K, B, din), ys: (K, B, C), lr: f32 scalar, h: i32 scalar.
    Returns (new_params..., loss_sum over the first h steps).
    """
    k = xs.shape[0]

    def body(q, carry):
        params, loss_sum = carry
        active = q < h
        lr_q = jnp.where(active, lr, 0.0)
        out = train_step(params, xs[q], ys[q], lr_q)
        new_params = list(out[:-1])
        loss_sum = loss_sum + jnp.where(active, out[-1], 0.0)
        return (new_params, loss_sum)

    params, loss_sum = jax.lax.fori_loop(
        0, k, body, (list(params), jnp.float32(0.0))
    )
    return tuple(params) + (loss_sum,)


def eval_step(params, x, y_onehot):
    """Summed loss and correct-count over an eval batch. The Rust side
    accumulates across batches and divides."""
    logits = forward(params, x)
    loss, _ = sx_k.softmax_xent(logits, y_onehot)
    pred = jnp.argmax(logits, axis=-1)
    label = jnp.argmax(y_onehot, axis=-1)
    correct = jnp.sum((pred == label).astype(jnp.float32))
    return jnp.sum(loss), correct


def init_params(key, sizes):
    """He-uniform init (python-side tests only; Rust owns init at runtime)."""
    params = []
    for i in range(len(sizes) - 1):
        key, k1 = jax.random.split(key)
        fan_in = sizes[i]
        bound = jnp.sqrt(6.0 / fan_in)
        w = jax.random.uniform(
            k1, (sizes[i], sizes[i + 1]), jnp.float32, -bound, bound
        )
        b = jnp.zeros((sizes[i + 1],), jnp.float32)
        params += [w, b]
    return params


def param_shapes(sizes):
    """[(shape, name), ...] in the flat argument order used everywhere."""
    out = []
    for i in range(len(sizes) - 1):
        out.append(((sizes[i], sizes[i + 1]), f"w{i}"))
        out.append(((sizes[i + 1],), f"b{i}"))
    return out


def num_params(sizes):
    return sum(
        sizes[i] * sizes[i + 1] + sizes[i + 1] for i in range(len(sizes) - 1)
    )

"""AOT path: the lowered HLO text must be non-trivial, parseable-looking,
and the meta description must match the model zoo. (The authoritative
load-and-execute check lives on the Rust side: rust/tests/integration.rs.)
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_roundtrip_shapes():
    lowered = aot.lower_train_step([12, 6, 4], batch=8)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # 4 params + x + y + lr inputs, 4 params + loss outputs.
    assert "parameter(6)" in text
    assert "f32[8,12]" in text  # the batch input


def test_lower_eval_has_two_outputs():
    lowered = aot.lower_eval([12, 6, 4], batch=16)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[16,12]" in text


def test_train_step_numerics_after_lowering():
    """Executing the lowered artifact (via jax compile of the same fn)
    equals calling train_step eagerly — guards against lowering bugs."""
    sizes = [12, 6, 4]
    params = model.init_params(jax.random.PRNGKey(0), sizes)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 12)).astype(np.float32)
    y = np.eye(4, dtype=np.float32)[rng.integers(0, 4, 8)]
    lr = jnp.float32(0.1)
    eager = model.train_step(params, x, y, lr)
    lowered = aot.lower_train_step(sizes, batch=8)
    compiled = lowered.compile()
    aotted = compiled(*params, x, y, lr)
    for a, b in zip(eager, aotted):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_emitted_artifacts_exist_and_match_meta():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    meta_path = os.path.join(art, "meta.json")
    if not os.path.exists(meta_path):
        pytest.skip("run `make artifacts` first")
    meta = json.load(open(meta_path))
    assert meta["train_batch"] >= 1 and meta["eval_batch"] >= 1
    for name, info in meta["models"].items():
        assert info["sizes"] == model.MODELS[name]
        assert info["num_params"] == model.num_params(info["sizes"])
        for key in ("train_step", "eval"):
            p = os.path.join(art, info[key])
            assert os.path.exists(p), p
            head = open(p).read(512)
            assert "HloModule" in head


def test_perf_report_structure_sane():
    rep = aot.perf_report([784, 32, 10], 32)
    assert "fwd_layer0" in rep and "bwd_gw_layer1" in rep
    for v in rep.values():
        assert v["vmem_bytes"] <= 4 * 1024 * 1024
        assert 0 < v["mxu_utilization"] <= 1.0

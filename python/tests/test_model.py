"""L2 correctness: model fwd/bwd (custom_vjp over Pallas kernels) vs the
reference composition differentiated by jax.grad, plus train/eval semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=10, deadline=None)


def make_batch(batch, din, c, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, din)).astype(np.float32)
    y = np.eye(c, dtype=np.float32)[rng.integers(0, c, batch)]
    return x, y


def test_forward_matches_ref_all_models():
    for name, sizes in model.MODELS.items():
        params = model.init_params(jax.random.PRNGKey(1), sizes)
        x, _ = make_batch(8, sizes[0], sizes[-1], 3)
        out = model.forward(params, x)
        expect = ref.mlp_forward_ref(params, x)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    h=st.integers(2, 40),
    batch=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_grads_match_ref_autodiff(h, batch, seed):
    """Hand-written custom_vjp backward == jax.grad of the pure-jnp ref."""
    sizes = [13, h, 6]
    params = model.init_params(jax.random.PRNGKey(seed % 1000), sizes)
    x, y = make_batch(batch, 13, 6, seed)
    g_ours = jax.grad(model.loss_fn)(params, x, y)
    g_ref = jax.grad(ref.mlp_loss_ref)(params, x, y)
    for a, b in zip(g_ours, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


def test_train_step_decreases_loss_on_fixed_batch():
    sizes = model.MODELS["mlp"]
    params = model.init_params(jax.random.PRNGKey(0), sizes)
    x, y = make_batch(32, 784, 10, 0)
    lr = jnp.float32(0.1)
    losses = []
    for _ in range(6):
        out = model.train_step(params, x, y, lr)
        params, loss = list(out[:-1]), out[-1]
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_train_step_param_count_and_shapes():
    sizes = model.MODELS["mlp_deep"]
    params = model.init_params(jax.random.PRNGKey(2), sizes)
    x, y = make_batch(4, 784, 10, 1)
    out = model.train_step(params, x, y, jnp.float32(0.01))
    assert len(out) == len(params) + 1
    for p, o in zip(params, out[:-1]):
        assert p.shape == o.shape
    assert out[-1].shape == ()


def test_grad_step_matches_train_step():
    """train_step == params - grad_step's scaled gradients."""
    sizes = [20, 8, 5]
    params = model.init_params(jax.random.PRNGKey(3), sizes)
    x, y = make_batch(8, 20, 5, 2)
    lr = jnp.float32(0.05)
    stepped = model.train_step(params, x, y, lr)
    scaled = model.grad_step(params, x, y, lr)
    assert np.allclose(float(stepped[-1]), float(scaled[-1]))
    for p, s, t in zip(params, scaled[:-1], stepped[:-1]):
        np.testing.assert_allclose(p - s, t, rtol=1e-5, atol=1e-6)


def test_eval_step_counts():
    sizes = [10, 4, 3]
    params = model.init_params(jax.random.PRNGKey(4), sizes)
    x, y = make_batch(16, 10, 3, 5)
    loss_sum, correct = model.eval_step(params, x, y)
    logits = ref.mlp_forward_ref(params, x)
    rl, _ = ref.softmax_xent_ref(logits, y)
    np.testing.assert_allclose(float(loss_sum), float(np.sum(rl)), rtol=1e-4)
    acc_ref = np.sum(np.argmax(logits, 1) == np.argmax(y, 1))
    assert float(correct) == float(acc_ref)
    assert 0 <= float(correct) <= 16


def test_num_params_matches_init():
    for name, sizes in model.MODELS.items():
        params = model.init_params(jax.random.PRNGKey(0), sizes)
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total == model.num_params(sizes), name


def test_param_shapes_order():
    shapes = model.param_shapes([784, 32, 10])
    assert [n for _, n in shapes] == ["w0", "b0", "w1", "b1"]
    assert shapes[0][0] == (784, 32) and shapes[1][0] == (32,)

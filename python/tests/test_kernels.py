"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including the padding-relevant non-multiples of
block sizes) and values; assert_allclose is the core signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense as dense_k
from compile.kernels import softmax_xent as sx_k
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- dense ---

@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 200),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, seed):
    x, w, b = rand((m, k), seed), rand((k, n), seed + 1), rand((n,), seed + 2)
    out = dense_k.dense(x, w, b)
    ref_out = ref.dense_ref(x, w, b)
    np.testing.assert_allclose(out, ref_out, rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 50),
    k=st.integers(1, 150),
    n=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x, w = rand((m, k), seed), rand((k, n), seed + 1)
    np.testing.assert_allclose(
        dense_k.matmul(x, w), ref.matmul_ref(x, w), rtol=2e-5, atol=2e-5
    )


def test_dense_paper_shapes():
    """The exact layer shapes of the paper's MNIST MLP (784, 32, 10)."""
    for (m, k, n) in [(32, 784, 32), (32, 32, 10), (256, 784, 32)]:
        x, w, b = rand((m, k), 7), rand((k, n), 8), rand((n,), 9)
        # K=784 reduces in a different order than the reference dot; allow
        # accumulation-order error proportional to sqrt(K).
        np.testing.assert_allclose(
            dense_k.dense(x, w, b), ref.dense_ref(x, w, b),
            rtol=1e-4, atol=1e-3,
        )


def test_dense_zero_bias_is_matmul():
    x, w = rand((17, 33), 3), rand((33, 12), 4)
    b = np.zeros(12, np.float32)
    np.testing.assert_allclose(
        dense_k.dense(x, w, b), dense_k.matmul(x, w), rtol=1e-6, atol=1e-6
    )


def test_pick_blocks_within_vmem_budget():
    for (m, k, n) in [(32, 784, 32), (1024, 1024, 1024), (1, 1, 1),
                      (256, 100000, 8)]:
        bm, bk, bn = dense_k.pick_blocks(m, k, n)
        working = bm * bk + bk * bn + bm * bn
        assert working * 4 <= 4 * 1024 * 1024, (m, k, n, bm, bk, bn)


def test_vmem_report_fields():
    rep = dense_k.vmem_report(32, 784, 32)
    assert 0 < rep["mxu_utilization"] <= 1.0
    assert rep["vmem_bytes"] > 0 and rep["hbm_read_floats"] > 0


# ---------------------------------------------------------- softmax xent ---

@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    c=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 30.0),
)
def test_softmax_xent_matches_ref(m, c, seed, scale):
    logits = rand((m, c), seed, scale)
    labels = np.random.default_rng(seed + 1).integers(0, c, m)
    y = np.eye(c, dtype=np.float32)[labels]
    loss, probs = sx_k.softmax_xent(logits, y)
    rloss, rprobs = ref.softmax_xent_ref(logits, y)
    np.testing.assert_allclose(loss, rloss, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(probs, rprobs, rtol=1e-4, atol=1e-6)


def test_softmax_xent_extreme_logits_stable():
    """Max-subtraction must keep huge logits finite (no inf/nan)."""
    logits = np.array([[1000.0, -1000.0, 0.0], [-1e8, 1e8, 0.0]], np.float32)
    y = np.eye(3, dtype=np.float32)[[0, 1]]
    loss, probs = sx_k.softmax_xent(logits, y)
    assert np.all(np.isfinite(loss)) and np.all(np.isfinite(probs))
    np.testing.assert_allclose(loss, [0.0, 0.0], atol=1e-5)


def test_softmax_probs_sum_to_one():
    logits = rand((33, 10), 5, 3.0)
    y = np.eye(10, dtype=np.float32)[np.zeros(33, int)]
    _, probs = sx_k.softmax_xent(logits, y)
    np.testing.assert_allclose(np.sum(probs, axis=1), np.ones(33), rtol=1e-5)


def test_uniform_logits_loss_is_log_c():
    c = 10
    logits = np.zeros((8, c), np.float32)
    y = np.eye(c, dtype=np.float32)[np.arange(8) % c]
    loss, _ = sx_k.softmax_xent(logits, y)
    np.testing.assert_allclose(loss, np.full(8, np.log(c)), rtol=1e-5)
